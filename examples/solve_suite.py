"""End-to-end driver (the paper's workload): solve a benchmark suite and
print a Table-1 style report.

    PYTHONPATH=src python examples/solve_suite.py [--full]
"""
import sys
import time

from repro.core import graph, solver

SUITE = [("myciel3", 5), ("petersen", 4), ("queen5_5", 18),
         ("queen6_6", 25), ("myciel4", 10), ("desargues", 6)]
if "--full" in sys.argv:
    SUITE += [("mcgee", 7), ("dyck", 7), ("queen7_7", 35)]

print(f"{'name':<12} {'|V|':>4} {'tw':>4} {'exact':>6} "
      f"{'time(s)':>8} {'Exp':>10}")
total_t, total_exp = 0.0, 0
for key, want in SUITE:
    g = graph.REGISTRY[key]()
    t0 = time.time()
    res = solver.solve(g, cap=1 << 18, block=1 << 10)
    dt = time.time() - t0
    total_t += dt
    total_exp += res.expanded
    flag = "" if res.width == want else f"  (expected {want}!)"
    print(f"{key:<12} {g.n:>4} {res.width:>4} {str(res.exact):>6} "
          f"{dt:>8.2f} {res.expanded:>10}{flag}")
print(f"\ntotal: {total_t:.1f}s, {total_exp} states "
      f"({total_exp / max(total_t, 1e-9):.0f} states/s)")
