"""Distributed treewidth on a multi-device mesh (8 forced host devices):
the paper's wavefront sharded with hash-routed all_to_all dedup, with a
mid-run checkpoint + elastic restart onto fewer devices.

    PYTHONPATH=src python examples/distributed_tw.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                          # noqa: E402
from repro.core import bounds, distributed, graph   # noqa: E402

g = graph.queen(5)
mesh = distributed.make_solver_mesh()
print(f"mesh: {mesh.devices.size} devices | graph {g.name} n={g.n}")

res = distributed.solve_distributed(g, mesh, cap_local=1 << 12,
                                    block=1 << 7, verbose=True)
print(f"treewidth = {res.width} (exact={res.exact}, "
      f"states={res.expanded})")

# ---- checkpoint mid-decision, resume on a SMALLER mesh (elastic restart)
clique = bounds.greedy_max_clique(g)
ckpts = []
feasible, _, _ = distributed.decide_distributed(
    g, 18, clique, mesh, cap_local=1 << 12, block=1 << 7,
    checkpoint_cb=lambda c: ckpts.append(c))
mid = ckpts[len(ckpts) // 2]
mesh4 = distributed.make_solver_mesh(jax.devices()[:4])
feasible2, _, _ = distributed.decide_distributed(
    g, 18, clique, mesh4, cap_local=1 << 13, block=1 << 7, resume=mid)
print(f"k=18 feasible: 8-dev={feasible}, resumed-on-4-dev={feasible2}")
assert feasible == feasible2
